"""Server-side admission control (docs/http.md §Admission).

Sits between the HTTP handler threads and the router: every completion
request takes a :class:`Ticket` here BEFORE touching any engine.  The
controller enforces

  * a queue cap — more than ``max_queue`` undispatched tickets rejects
    with :class:`QueueFull` (the server maps it to HTTP 429 +
    ``Retry-After``) without perturbing anything already running;
  * a dispatch window — at most ``max_active`` tickets are dispatched
    (= submitted to an engine) at once, so the engines' own waiting
    queues stay shallow and priority reordering happens HERE, where the
    full picture (tenant, priority, arrival) is visible;
  * dispatch order: priority desc, then per-tenant fair share (fewest
    in-flight requests first — a tenant flooding the queue cannot starve
    others at equal priority), then FIFO arrival.

The scheduler below repeats the priority-then-FIFO ordering for
whatever does reach an engine queue, and its preemption victim choice
is lowest-priority-then-latest-arrival — so priorities hold end to end:
admission, engine queueing, and block-pressure eviction.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Dict, List, Optional


class QueueFull(Exception):
    """Admission queue at capacity; carries the Retry-After hint (s)."""

    def __init__(self, retry_after: int = 1):
        super().__init__(f"admission queue full; retry after {retry_after}s")
        self.retry_after = retry_after


class Closed(Exception):
    """Controller draining/shut down; server maps it to HTTP 503."""


@dataclasses.dataclass
class Ticket:
    """One request's admission handle (created by ``submit``)."""

    seq: int                      # arrival order (monotonic)
    priority: int
    tenant: str
    dispatched: threading.Event = dataclasses.field(
        default_factory=threading.Event)
    cancelled: bool = False
    released: bool = False


class AdmissionController:
    def __init__(self, max_queue: int = 64,
                 max_active: Optional[int] = None,
                 retry_after_s: int = 1):
        self.max_queue = max_queue
        self.max_active = max_active           # None = unbounded dispatch
        self.retry_after_s = retry_after_s
        self._lock = threading.Lock()
        self._pending: List[Ticket] = []       # undispatched, arrival order
        self._inflight: Dict[str, int] = {}    # tenant -> dispatched count
        self._active = 0
        self._seq = 0
        self._closed = False
        self.n_admitted = 0
        self.n_rejected = 0
        self.n_dispatched = 0

    # -- client side --------------------------------------------------------
    def submit(self, *, priority: int = 0,
               tenant: str = "anonymous") -> Ticket:
        """Take a ticket; raises :class:`QueueFull` when the undispatched
        queue is at capacity, :class:`Closed` while draining."""
        with self._lock:
            if self._closed:
                raise Closed()
            if len(self._pending) >= self.max_queue:
                self.n_rejected += 1
                raise QueueFull(self.retry_after_s)
            t = Ticket(seq=self._seq, priority=priority, tenant=tenant)
            self._seq += 1
            self._pending.append(t)
            self.n_admitted += 1
            self._pump()
        return t

    def wait(self, ticket: Ticket, timeout: Optional[float] = None) -> bool:
        """Block until the ticket is dispatched (True) or timeout."""
        return ticket.dispatched.wait(timeout)

    def release(self, ticket: Ticket):
        """Return the ticket's dispatch slot (request finished, aborted,
        or client gone); idempotent.  Cancels instead if undispatched."""
        with self._lock:
            if ticket.released:
                return
            ticket.released = True
            if not ticket.dispatched.is_set():
                ticket.cancelled = True
                try:
                    self._pending.remove(ticket)
                except ValueError:
                    pass
                return
            self._active -= 1
            n = self._inflight.get(ticket.tenant, 1) - 1
            if n:
                self._inflight[ticket.tenant] = n
            else:
                self._inflight.pop(ticket.tenant, None)
            self._pump()

    # -- dispatch ------------------------------------------------------------
    def _pump(self):
        """Dispatch pending tickets while the window has room (caller
        holds the lock).  Order: priority desc, least tenant in-flight,
        FIFO arrival — see the module docstring."""
        while self._pending and (self.max_active is None
                                 or self._active < self.max_active):
            best = min(self._pending,
                       key=lambda t: (-t.priority,
                                      self._inflight.get(t.tenant, 0),
                                      t.seq))
            self._pending.remove(best)
            self._active += 1
            self._inflight[best.tenant] = \
                self._inflight.get(best.tenant, 0) + 1
            self.n_dispatched += 1
            best.dispatched.set()

    # -- lifecycle / introspection -------------------------------------------
    def close(self):
        """Stop admitting; pending undispatched tickets are cancelled
        (their waiters see ``cancelled`` after a spurious dispatch)."""
        with self._lock:
            self._closed = True
            for t in self._pending:
                t.cancelled = True
                t.dispatched.set()     # wake waiters; they check cancelled
            self._pending.clear()

    def snapshot(self) -> Dict[str, int]:
        with self._lock:
            return {
                "admission_pending": len(self._pending),
                "admission_active": self._active,
                "admission_admitted_total": self.n_admitted,
                "admission_rejected_total": self.n_rejected,
                "admission_dispatched_total": self.n_dispatched,
            }
