"""In-process engine fleet: replicas + least-loaded-KV routing
(docs/http.md §Router).

Each :class:`EngineReplica` owns ONE loop thread that is the only
thread ever touching its engine: HTTP handler threads enqueue
submissions/aborts onto thread-safe queues, the loop drains them
between ``step()`` calls and fans each request's ``RequestOutput``
stream out to a per-request queue the handler consumes.  This keeps the
engine's single-driver threading contract (docs/serving.md) while any
number of connections stream concurrently.

The :class:`Router` places each request on the healthy replica with the
most free KV blocks (per-replica ``engine.load()`` feedback), breaking
ties by total load (queued + active requests) then replica order — so
K concurrent requests spread across the fleet instead of piling onto
replica 0.  Health = loop thread alive and no crash recorded;
``shutdown(drain=True)`` stops new work and lets every replica run its
in-flight requests to completion before stopping the engines.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.core.sampling_params import SamplingParams


class ReplicaUnavailable(RuntimeError):
    """No healthy replica can take the request (server maps to 503)."""


class _Submit:
    __slots__ = ("prompt_ids", "params", "arrival_t", "done", "rid",
                 "out_q", "error")

    def __init__(self, prompt_ids, params, arrival_t):
        self.prompt_ids = prompt_ids
        self.params = params
        self.arrival_t = arrival_t
        self.done = threading.Event()
        self.rid: Optional[int] = None
        self.out_q: Optional["queue.Queue"] = None
        self.error: Optional[BaseException] = None


class EngineReplica:
    """One engine + its serving loop thread."""

    def __init__(self, name: str, engine):
        self.name = name
        self.engine = engine
        self._submit_q: "queue.Queue[_Submit]" = queue.Queue()
        self._abort_q: "queue.Queue[int]" = queue.Queue()
        self._streams: Dict[int, "queue.Queue"] = {}
        self._streams_lock = threading.Lock()
        self._wake = threading.Event()
        self._draining = False
        self._stop = False
        self.error: Optional[BaseException] = None
        self.heartbeat = 0.0
        self.peak_busy_blocks = 0       # router-balance accounting (bench)
        self._thread = threading.Thread(target=self._loop,
                                        name=f"replica-{name}", daemon=True)

    def start(self) -> "EngineReplica":
        self._thread.start()
        return self

    # -- handler-thread surface ---------------------------------------------
    @property
    def healthy(self) -> bool:
        return (self._thread.is_alive() and self.error is None
                and not self._draining)

    def load(self) -> Dict[str, int]:
        """Engine load snapshot plus the not-yet-ingested submit backlog.
        Reads only counters/lens — safe from any thread.  The backlog is
        split by tier: offline submissions ride ``offline_queue_depth``
        so a deep batch backlog never repels ONLINE placements
        (docs/hybrid.md — the engine runs offline work in slack only)."""
        snap = self.engine.load()
        backlog_online = backlog_offline = 0
        with self._submit_q.mutex:
            for sub in self._submit_q.queue:
                if getattr(sub.params, "tier", "online") == "offline":
                    backlog_offline += 1
                else:
                    backlog_online += 1
        snap["queue_depth"] += backlog_online
        snap["offline_queue_depth"] = (
            snap.get("offline_queue_depth", 0) + backlog_offline)
        return snap

    def submit(self, prompt_ids: List[int], params: SamplingParams,
               arrival_t: Optional[float] = None,
               timeout: float = 120.0) -> Tuple[int, "queue.Queue"]:
        """Hand a request to the loop thread; returns ``(request_id,
        output_queue)`` once admitted.  The queue yields this request's
        ``RequestOutput`` increments in order; the ``finished=True``
        increment is the last item."""
        if not self.healthy:
            raise ReplicaUnavailable(f"replica {self.name} is not serving")
        sub = _Submit(prompt_ids, params, arrival_t)
        self._submit_q.put(sub)
        self._wake.set()
        # a step mid-flight (first-request jit compile) can hold the loop
        # for seconds — the admission wait is bounded, not instant
        if not sub.done.wait(timeout):
            raise ReplicaUnavailable(
                f"replica {self.name} did not admit within {timeout}s")
        if sub.error is not None:
            raise sub.error
        # the stream queue rides on the _Submit itself: looking it up in
        # _streams here would race a request fast enough to finish (and
        # be popped by _route) before this thread wakes
        return sub.rid, sub.out_q

    def abort(self, request_id: int):
        self._abort_q.put(request_id)
        self._wake.set()

    def metrics(self) -> Dict[str, Any]:
        return self.engine.metrics()

    # -- serving loop --------------------------------------------------------
    def _loop(self):
        try:
            while not self._stop:
                self._drain_control_queues()
                if self.engine.has_work:
                    outs = self.engine.step()
                    self._route(outs)
                    self._track_occupancy()
                elif self._draining:
                    break
                else:
                    self._wake.wait(0.002)
                    self._wake.clear()
        except BaseException as e:          # noqa: BLE001 — recorded, fleet
            self.error = e                  # health check reroutes traffic
            self._fail_streams(e)
        finally:
            try:
                self.engine.shutdown()
            except Exception:
                pass

    def _drain_control_queues(self):
        self.heartbeat = time.monotonic()
        while True:
            try:
                sub = self._submit_q.get_nowait()
            except queue.Empty:
                break
            try:
                rid = self.engine.add_request(sub.prompt_ids, sub.params,
                                              arrival_t=sub.arrival_t)
                sub.out_q = queue.Queue()
                with self._streams_lock:
                    self._streams[rid] = sub.out_q
                sub.rid = rid
            except Exception as e:
                sub.error = e
            sub.done.set()
        while True:
            try:
                rid = self._abort_q.get_nowait()
            except queue.Empty:
                break
            self.engine.abort(rid)

    def _route(self, outs):
        for out in outs:
            with self._streams_lock:
                q = self._streams.get(out.request_id)
                if out.finished:
                    self._streams.pop(out.request_id, None)
            if q is not None:
                q.put(out)

    def _track_occupancy(self):
        snap = self.engine.load()
        busy = snap["kv_blocks_total"] - snap["kv_blocks_free"]
        if busy > self.peak_busy_blocks:
            self.peak_busy_blocks = busy

    def _fail_streams(self, exc: BaseException):
        with self._streams_lock:
            streams, self._streams = list(self._streams.values()), {}
        for q in streams:
            q.put(exc)

    # -- lifecycle -----------------------------------------------------------
    def drain(self, timeout: float = 60.0) -> bool:
        """Stop taking new requests, run in-flight work to completion,
        shut the engine down.  Returns True on a clean drain."""
        self._draining = True
        self._wake.set()
        self._thread.join(timeout)
        clean = not self._thread.is_alive()
        if not clean:
            self._stop = True
            self._wake.set()
            self._thread.join(5.0)
        return clean

    def kill(self):
        """Hard stop (tests); in-flight requests get no final output."""
        self._stop = True
        self._wake.set()
        self._thread.join(10.0)


class Router:
    """Least-loaded-KV placement over N replicas."""

    def __init__(self, replicas: List[EngineReplica]):
        if not replicas:
            raise ValueError("router needs at least one replica")
        self.replicas = replicas
        self._lock = threading.Lock()
        self.routed: Dict[str, int] = {r.name: 0 for r in replicas}

    def start(self) -> "Router":
        for r in self.replicas:
            r.start()
        return self

    def pick(self) -> EngineReplica:
        """The healthy replica with the most free KV blocks; ties fall to
        the least total load (queued + active), then replica order."""
        ranked = []
        for i, r in enumerate(self.replicas):
            if not r.healthy:
                continue
            snap = r.load()
            ranked.append((-snap["kv_blocks_free"],
                           snap["queue_depth"] + snap["active_requests"],
                           i, r))
        if not ranked:
            raise ReplicaUnavailable("no healthy replica")
        return min(ranked)[3]

    def submit(self, prompt_ids: List[int], params: SamplingParams,
               arrival_t: Optional[float] = None,
               ) -> Tuple[EngineReplica, int, "queue.Queue"]:
        with self._lock:
            r = self.pick()
            self.routed[r.name] += 1
        rid, out_q = r.submit(prompt_ids, params, arrival_t)
        return r, rid, out_q

    def health(self) -> Dict[str, Dict[str, Any]]:
        out = {}
        for r in self.replicas:
            entry: Dict[str, Any] = {"healthy": r.healthy}
            if r.error is not None:
                entry["error"] = repr(r.error)
            if r.healthy:
                entry.update(r.load())
            out[r.name] = entry
        return out

    def metrics(self) -> Dict[str, Dict[str, Any]]:
        return {r.name: r.metrics() for r in self.replicas
                if r.error is None}

    def shutdown(self, drain: bool = True, timeout: float = 60.0):
        if drain:
            threads = [threading.Thread(target=r.drain, args=(timeout,))
                       for r in self.replicas]
            for t in threads:
                t.start()
            for t in threads:
                t.join(timeout + 5.0)
        else:
            for r in self.replicas:
                r.kill()
