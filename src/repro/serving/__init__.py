"""HTTP serving front-end over the engine (docs/http.md).

The first transport layer of the reproduction: an OpenAI-style
``/v1/completions`` endpoint with SSE streaming (``server.py``), queue
caps + per-tenant fair queueing + request priorities (``admission.py``),
and N in-process engine replicas behind least-loaded-KV routing
(``router.py``).  Everything is stdlib-only — ``http.server`` +
``socket`` + ``threading`` — so the layer adds no dependencies.
"""
from repro.serving.admission import AdmissionController, QueueFull, Ticket
from repro.serving.protocol import (
    ProtocolError,
    completion_chunk,
    completion_response,
    parse_completion_request,
    render_prometheus,
    sse_event,
    SSE_DONE,
)
from repro.serving.router import EngineReplica, Router
from repro.serving.server import CompletionServer

__all__ = [
    "AdmissionController", "QueueFull", "Ticket",
    "ProtocolError", "completion_chunk", "completion_response",
    "parse_completion_request", "render_prometheus", "sse_event",
    "SSE_DONE",
    "EngineReplica", "Router", "CompletionServer",
]
