"""A deterministic stand-in engine for transport-layer tests.

Implements exactly the engine surface the serving layer touches —
``add_request`` / ``step`` / ``abort`` / ``load`` / ``metrics`` /
``has_work`` / ``shutdown`` — with a trivial arithmetic "model": token
``k`` of a completion is ``(sum(prompt) + k) % vocab``.  One token per
request per ``step()``, ``SamplingParams.n > 1`` emits fork streams
with a per-fork offset.  Lets protocol, router, and admission tests run
the full HTTP path in milliseconds, with no JAX compile anywhere
(tests/test_http.py, tests/test_router.py); the real-engine e2e parity
lives next to it in the same files.
"""
from __future__ import annotations

import itertools
import threading
from typing import Dict, List, Optional

from repro.core.request import (
    ForkOutput,
    RequestMetrics,
    RequestOutput,
    RequestState,
)
from repro.core.sampling_params import SamplingParams
from repro.core.sequence import SeqStatus, Sequence


class MockEngine:
    """Deterministic fake with real RequestOutput framing."""

    BLOCK = 4      # tokens per fake KV block (occupancy accounting)

    def __init__(self, vocab_size: int = 64, kv_blocks: int = 64,
                 start_id: int = 0):
        self.vocab_size = vocab_size
        self.kv_blocks = kv_blocks
        self._ids = itertools.count(start_id)
        self._live: Dict[int, dict] = {}
        self._lock = threading.Lock()
        self.n_aborts = 0
        self.n_finished = 0
        self.n_steps = 0
        self._stopped = False

    # -- engine surface ------------------------------------------------------
    def add_request(self, prompt_ids: List[int], params: SamplingParams,
                    arrival_t: Optional[float] = None) -> int:
        rid = next(self._ids)
        seq = Sequence(rid, list(prompt_ids), params)
        seq.status = SeqStatus.RUNNING
        with self._lock:
            self._live[rid] = {"seq": seq, "streamed": 0,
                               "forks": [list() for _ in range(params.n - 1)],
                               "aborted": False}
        return rid

    def abort(self, request_id: int, fork: Optional[int] = None) -> bool:
        with self._lock:
            r = self._live.get(request_id)
            if r is None:
                return False
            r["aborted"] = True
            self.n_aborts += 1
        return True

    def _token(self, seq: Sequence, k: int, fork: int = 0) -> int:
        return (sum(seq.prompt_ids) + 31 * fork + k) % self.vocab_size

    def step(self) -> List[RequestOutput]:
        self.n_steps += 1
        outs: List[RequestOutput] = []
        with self._lock:
            for rid in list(self._live):
                r = self._live[rid]
                seq: Sequence = r["seq"]
                want = seq.params.max_new_tokens
                if r["aborted"]:
                    seq.status = SeqStatus.ABORTED
                    seq.finish_reason = "abort"
                else:
                    k = len(seq.output_ids)
                    seq.output_ids.append(self._token(seq, k))
                    for fi, f in enumerate(r["forks"]):
                        f.append(self._token(seq, len(f), fi + 1))
                    if len(seq.output_ids) >= want:
                        seq.status = SeqStatus.FINISHED
                        seq.finish_reason = "length"
                done = seq.status in (SeqStatus.FINISHED, SeqStatus.ABORTED)
                new = seq.output_ids[r["streamed"]:]
                r["streamed"] = len(seq.output_ids)
                forks = [ForkOutput(fi + 1, ([] if r["aborted"] else [f[-1]]),
                                    list(f), done, seq.finish_reason if done
                                    else None)
                         for fi, f in enumerate(r["forks"])] or None
                outs.append(RequestOutput(
                    rid, new, list(seq.output_ids), done,
                    RequestState.of(seq), seq.finish_reason if done else None,
                    RequestMetrics.of(seq) if done else None, seq,
                    forks=forks))
                if done:
                    self._live.pop(rid)
                    self.n_finished += seq.status == SeqStatus.FINISHED
        return outs

    @property
    def has_work(self) -> bool:
        return bool(self._live)

    def load(self) -> Dict[str, int]:
        with self._lock:
            busy = sum(-(-(r["seq"].length) // self.BLOCK) * seq_count(r)
                       for r in self._live.values())
        return {"active_requests": len(self._live), "queue_depth": 0,
                "kv_blocks_total": self.kv_blocks,
                "kv_blocks_free": max(0, self.kv_blocks - busy)}

    def metrics(self) -> Dict[str, float]:
        load = self.load()
        return {"requests_finished": self.n_finished,
                "requests_aborted": self.n_aborts,
                "requests_active": load["active_requests"],
                "queue_depth": 0,
                "kv_blocks_total": load["kv_blocks_total"],
                "kv_blocks_free": load["kv_blocks_free"],
                "steps": self.n_steps}

    def shutdown(self):
        self._stopped = True


def seq_count(rec: dict) -> int:
    return 1 + len(rec["forks"])
