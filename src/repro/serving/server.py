"""Stdlib-only HTTP front-end: OpenAI-style completions over the engine
(docs/http.md).

Endpoints (all JSON unless noted):

  POST /v1/completions   completion request; ``"stream": true`` returns
                         Server-Sent Events (``data: {chunk}\\n\\n`` ...
                         ``data: [DONE]\\n\\n``), else the aggregate
                         completion object.  ``n > 1`` streams every
                         fork as its own choice index.  ``"tier":
                         "offline"`` marks best-effort batch traffic
                         (docs/hybrid.md).
  POST /v1/batches       offline batch enqueue: ``{"requests": [...]}``
                         of completion bodies, all forced to the
                         offline tier; blocks until every one finishes
                         and returns their completion objects in order.
                         Offline queue overflow is 503 + a tier body,
                         not 429 (batch clients back off, not retry).
  GET  /v1/models        the served model list.
  GET  /health           router + replica health.
  GET  /metrics          Prometheus text of every replica's
                         ``engine.metrics()`` + admission counters.

Built on ``http.server.ThreadingHTTPServer`` — one stdlib thread per
connection.  Handler threads never touch an engine: admission happens
in :class:`~repro.serving.admission.AdmissionController`, placement in
:class:`~repro.serving.router.Router`, and all engine calls run on the
chosen replica's loop thread.  A client that disconnects mid-stream
(write fails) gets its request aborted on the replica, so KV blocks
are reclaimed (tests/test_http.py e2e).
"""
from __future__ import annotations

import json
import queue
import select
import socket
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, Optional, Tuple

from repro.core.request import RequestState
from repro.serving import admission as adm
from repro.serving import protocol as proto
from repro.serving.router import ReplicaUnavailable, Router

# streamed requests wait this long for the next RequestOutput before the
# server gives up on the replica (first-token jit compiles take seconds,
# so this is generous)
STREAM_IDLE_TIMEOUT_S = 120.0


class _HTTPServer(ThreadingHTTPServer):
    daemon_threads = True
    ctx: "CompletionServer"


class CompletionServer:
    """The serving front-end: router + admission + HTTP transport."""

    def __init__(self, router: Router, *, vocab_size: int,
                 model_name: str = "repro", max_queue: int = 64,
                 max_active: Optional[int] = None, max_tokens_cap: int = 0,
                 host: str = "127.0.0.1", port: int = 0):
        self.router = router
        self.vocab_size = vocab_size
        self.model_name = model_name
        self.max_tokens_cap = max_tokens_cap
        self.admission = adm.AdmissionController(max_queue=max_queue,
                                                 max_active=max_active)
        self.n_disconnects = 0
        self._httpd = _HTTPServer((host, port), _Handler)
        self._httpd.ctx = self
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="http-server", daemon=True)
        self._closed = False

    @property
    def address(self) -> Tuple[str, int]:
        return self._httpd.server_address[:2]

    def start(self) -> "CompletionServer":
        self.router.start()
        self._thread.start()
        return self

    def close(self, drain: bool = True, timeout: float = 60.0):
        """Drain-on-shutdown: stop admitting (new requests see 503), let
        in-flight requests finish, then stop replicas and the listener."""
        if self._closed:
            return
        self._closed = True
        self.admission.close()
        self.router.shutdown(drain=drain, timeout=timeout)
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(5.0)


class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"

    @property
    def ctx(self) -> CompletionServer:
        return self.server.ctx          # type: ignore[attr-defined]

    def log_message(self, fmt, *args):  # quiet: tests/benches parse stdout
        pass

    # -- plumbing ------------------------------------------------------------
    def _send_json(self, code: int, payload: Dict[str, Any],
                   headers: Optional[Dict[str, str]] = None):
        body = json.dumps(payload, sort_keys=True).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        for k, v in (headers or {}).items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(body)

    def _error(self, code: int, message: str,
               headers: Optional[Dict[str, str]] = None,
               body_extra: Optional[Dict[str, Any]] = None):
        err: Dict[str, Any] = {"message": message, "code": code}
        err.update(body_extra or {})
        self._send_json(code, {"error": err}, headers)

    def _tenant(self, body: Dict[str, Any]) -> Optional[str]:
        key = self.headers.get("X-API-Key")
        if not key:
            auth = self.headers.get("Authorization", "")
            if auth.startswith("Bearer "):
                key = auth[len("Bearer "):].strip()
        return key or None

    # -- GET endpoints -------------------------------------------------------
    def do_GET(self):
        ctx = self.ctx
        if self.path == "/health":
            health = ctx.router.health()
            ok = any(h.get("healthy") for h in health.values())
            self._send_json(200 if ok else 503,
                            {"status": "ok" if ok else "unavailable",
                             "replicas": health})
        elif self.path == "/v1/models":
            self._send_json(200, {"object": "list", "data": [{
                "id": ctx.model_name, "object": "model",
                "owned_by": "repro"}]})
        elif self.path == "/metrics":
            text = proto.render_prometheus(
                ctx.router.metrics(),
                {**ctx.admission.snapshot(),
                 "http_disconnects_total": ctx.n_disconnects})
            body = text.encode()
            self.send_response(200)
            self.send_header("Content-Type",
                             "text/plain; version=0.0.4; charset=utf-8")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)
        else:
            self._error(404, f"no such endpoint: {self.path}")

    def _queue_full(self, e: "adm.QueueFull"):
        """Map a tier's queue overflow to its status: online -> 429 +
        Retry-After (interactive clients retry soon), offline -> 503 + a
        tier-carrying body (batch clients should back off).  Both bodies
        name the tier so callers can tell WHICH queue overflowed."""
        if e.tier == "offline":
            self._error(503, "offline admission queue full",
                        body_extra={"tier": "offline",
                                    "retry_after": e.retry_after})
        else:
            self._error(429, "admission queue full",
                        {"Retry-After": str(e.retry_after)},
                        body_extra={"tier": "online"})

    # -- POST /v1/completions, /v1/batches -----------------------------------
    def do_POST(self):
        if self.path == "/v1/batches":
            self._batches()
            return
        if self.path != "/v1/completions":
            self._error(404, f"no such endpoint: {self.path}")
            return
        ctx = self.ctx
        try:
            length = int(self.headers.get("Content-Length", 0))
            body = json.loads(self.rfile.read(length) or b"{}")
            req = proto.parse_completion_request(
                body, ctx.vocab_size, tenant=self._tenant(body),
                max_tokens_cap=ctx.max_tokens_cap)
        except (json.JSONDecodeError, UnicodeDecodeError):
            self._error(400, "request body is not valid JSON")
            return
        except proto.ProtocolError as e:
            self._error(400, str(e))
            return

        try:
            ticket = ctx.admission.submit(priority=req.priority,
                                          tenant=req.tenant, tier=req.tier)
        except adm.QueueFull as e:
            self._queue_full(e)
            return
        except adm.Closed:
            self._error(503, "server is draining")
            return

        try:
            ctx.admission.wait(ticket)
            if ticket.cancelled:
                self._error(503, "server is draining")
                return
            try:
                replica, rid, out_q = ctx.router.submit(
                    req.prompt_ids, req.sampling_params(),
                    arrival_t=time.monotonic())
            except (ReplicaUnavailable, ValueError) as e:
                self._error(503 if isinstance(e, ReplicaUnavailable)
                            else 400, str(e))
                return
            created = int(time.time())
            if req.stream:
                self._stream(req, replica, rid, out_q, created)
            else:
                self._aggregate(req, replica, rid, out_q, created)
        finally:
            ctx.admission.release(ticket)

    def _next_output(self, replica, rid, out_q):
        """The request's next RequestOutput, or None on replica failure
        (crash exceptions ride the same queue)."""
        try:
            out = out_q.get(timeout=STREAM_IDLE_TIMEOUT_S)
        except queue.Empty:
            replica.abort(rid)
            return None
        if isinstance(out, BaseException):
            return None
        return out

    def _stream(self, req, replica, rid, out_q, created):
        ctx = self.ctx
        self.send_response(200)
        self.send_header("Content-Type", "text/event-stream")
        self.send_header("Cache-Control", "no-cache")
        self.send_header("Connection", "close")
        self.end_headers()
        self.close_connection = True
        # backstop for a live-but-stalled reader: a zero receive window
        # never fails sendall, it blocks — bound the stall
        self.connection.settimeout(STREAM_IDLE_TIMEOUT_S)
        finished_idx = set()

        def emit(payload: bytes) -> bool:
            try:
                # a closed client often does NOT fail our writes: its FIN
                # leaves the kernel ACKing into an orphaned socket until
                # the window fills, wedging sendall forever.  An SSE
                # client never sends mid-stream, so readability + empty
                # peek IS the disconnect — detect it, don't await it.
                r, _, _ = select.select([self.connection], [], [], 0)
                if r and not self.connection.recv(1, socket.MSG_PEEK):
                    raise OSError("client closed the connection")
                self.wfile.write(payload)
                self.wfile.flush()
                return True
            except OSError:
                # client went away mid-stream: reclaim the KV blocks
                ctx.n_disconnects += 1
                replica.abort(rid)
                return False

        while True:
            out = self._next_output(replica, rid, out_q)
            if out is None:
                emit(proto.sse_event({"error": {
                    "message": "replica failed mid-stream", "code": 500}}))
                return
            # the primary choice can finish while forks keep the request
            # open (n > 1): detect it from the ``state`` SNAPSHOT taken on
            # the engine thread at emit time — never from the live ``seq``,
            # which the loop thread keeps mutating under this reader.  Its
            # finish chunk waits for an increment with an empty delta (or
            # the request close), so a final token landing after the
            # snapshot is never sealed off behind a finish_reason.
            primary_done = out.finished or out.state in (
                RequestState.FINISHED, RequestState.ABORTED)
            reason = out.finish_reason
            if reason is None and primary_done and out.seq is not None:
                reason = out.seq.finish_reason
            seal = (out.finished or (primary_done
                                     and not out.new_token_ids)) \
                and reason is not None
            # (choice index, delta, this-choice-finished, finish_reason)
            slices = [(0, list(out.new_token_ids), seal,
                       reason if seal else None)]
            for fo in out.forks or []:
                fdone = fo.finished and fo.finish_reason is not None
                slices.append((fo.index, list(fo.new_token_ids), fdone,
                               fo.finish_reason if fdone else None))
            for idx, delta, done, reason in slices:
                if idx in finished_idx or not (delta or done):
                    continue
                chunk = proto.completion_chunk(
                    rid, created, req.model, idx, delta,
                    reason if done else None)
                if done:
                    finished_idx.add(idx)
                if not emit(proto.sse_event(chunk)):
                    return
            if out.finished:
                emit(proto.SSE_DONE)
                return

    def _collect(self, req, replica, rid, out_q,
                 created) -> Optional[Dict[str, Any]]:
        """Drain a request's RequestOutputs to completion; the aggregate
        completion payload, or None on replica failure."""
        toks: Dict[int, list] = {0: []}
        reasons: Dict[int, Optional[str]] = {}
        while True:
            out = self._next_output(replica, rid, out_q)
            if out is None:
                return None
            toks[0].extend(out.new_token_ids)
            for fo in out.forks or []:
                toks.setdefault(fo.index, []).extend(fo.new_token_ids)
                if fo.finished:
                    reasons[fo.index] = fo.finish_reason
            if out.finished:
                reasons[0] = out.finish_reason
                break
        choices = [{"token_ids": toks[i], "finish_reason": reasons.get(i)}
                   for i in sorted(toks)]
        return proto.completion_response(
            rid, created, req.model, choices, len(req.prompt_ids))

    def _aggregate(self, req, replica, rid, out_q, created):
        payload = self._collect(req, replica, rid, out_q, created)
        if payload is None:
            self._error(500, "replica failed mid-request")
        else:
            self._send_json(200, payload)

    # -- POST /v1/batches ----------------------------------------------------
    def _batches(self):
        """Offline batch enqueue (docs/hybrid.md): every entry of the
        ``requests`` list is parsed as a completion body FORCED to the
        offline tier, submitted through admission (offline cap, no
        online window) + the router, and the response blocks until all
        of them finish.  The engines run them only in scheduler slack —
        a saturating batch here never delays online traffic."""
        ctx = self.ctx
        try:
            length = int(self.headers.get("Content-Length", 0))
            body = json.loads(self.rfile.read(length) or b"{}")
            if not isinstance(body, dict):
                raise proto.ProtocolError("request body must be a JSON object")
            entries = body.get("requests")
            if not isinstance(entries, list) or not entries:
                raise proto.ProtocolError(
                    "'requests' must be a non-empty list of completion "
                    "request objects")
            tenant = self._tenant(body)
            reqs = []
            for entry in entries:
                if not isinstance(entry, dict):
                    raise proto.ProtocolError(
                        "each batch entry must be a JSON object")
                entry = dict(entry, tier="offline", stream=False)
                reqs.append(proto.parse_completion_request(
                    entry, ctx.vocab_size, tenant=tenant,
                    max_tokens_cap=ctx.max_tokens_cap))
        except (json.JSONDecodeError, UnicodeDecodeError):
            self._error(400, "request body is not valid JSON")
            return
        except proto.ProtocolError as e:
            self._error(400, str(e))
            return

        tickets = []
        try:
            try:
                for r in reqs:
                    tickets.append(ctx.admission.submit(
                        priority=r.priority, tenant=r.tenant,
                        tier="offline"))
            except adm.QueueFull as e:
                self._queue_full(e)
                return
            except adm.Closed:
                self._error(503, "server is draining")
                return
            created = int(time.time())
            submitted = []
            try:
                for r in reqs:
                    replica, rid, out_q = ctx.router.submit(
                        r.prompt_ids, r.sampling_params(),
                        arrival_t=time.monotonic())
                    submitted.append((r, replica, rid, out_q))
            except (ReplicaUnavailable, ValueError) as e:
                for _, replica, rid, _ in submitted:
                    replica.abort(rid)
                self._error(503 if isinstance(e, ReplicaUnavailable)
                            else 400, str(e))
                return
            results = []
            for r, replica, rid, out_q in submitted:
                payload = self._collect(r, replica, rid, out_q, created)
                if payload is None:
                    for _, rep2, rid2, _ in submitted:
                        rep2.abort(rid2)
                    self._error(500, "replica failed mid-batch")
                    return
                results.append(payload)
            self._send_json(200, {"object": "batch", "created": created,
                                  "results": results})
        finally:
            for t in tickets:
                ctx.admission.release(t)
