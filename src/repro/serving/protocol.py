"""Wire protocol for the OpenAI-style completions front-end (docs/http.md).

Pure functions only — request parsing, SSE chunk framing, completion
JSON assembly, and Prometheus text rendering — so the whole layer is
golden-file testable without sockets (tests/test_http.py).  Every
builder takes the non-deterministic fields (request id, ``created``
timestamp) as explicit arguments; nothing in this module reads a clock.

The repo has no real tokenizer, so the prompt contract is token-id
first: ``prompt`` is a ``list[int]`` of token ids (the form every
bit-exactness test uses), or a ``str`` that is byte-level stub-encoded
(``2 + byte % (vocab - 2)`` — deterministic, keeps ids out of the
reserved 0/1 range).  Response ``text`` is the space-joined token ids;
the real ids always ride along in a ``token_ids`` extension field.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Union

from repro.core.sampling_params import SamplingParams

SSE_DONE = b"data: [DONE]\n\n"


class ProtocolError(ValueError):
    """Malformed client request; the server maps it to HTTP 400."""


def encode_prompt(prompt: Union[str, List[int]], vocab_size: int) -> List[int]:
    """Token ids for a request prompt: pass-through for ``list[int]``
    (range-checked), byte-level stub encoding for ``str``."""
    if isinstance(prompt, str):
        if not prompt:
            raise ProtocolError("prompt must be non-empty")
        return [2 + (b % (vocab_size - 2)) for b in prompt.encode("utf-8")]
    if isinstance(prompt, list) and prompt \
            and all(isinstance(t, int) and not isinstance(t, bool)
                    for t in prompt):
        bad = [t for t in prompt if not 0 <= t < vocab_size]
        if bad:
            raise ProtocolError(
                f"prompt token ids out of range [0, {vocab_size}): {bad[:4]}")
        return list(prompt)
    raise ProtocolError(
        "prompt must be a non-empty string or list of token ids")


def decode_text(token_ids) -> str:
    """Stub detokenization: space-joined token ids (reversible, stable)."""
    return " ".join(str(int(t)) for t in token_ids)


@dataclasses.dataclass
class CompletionRequest:
    """A parsed, validated /v1/completions body."""

    prompt_ids: List[int]
    model: str
    max_tokens: int = 16
    temperature: float = 1.0
    top_p: float = 1.0
    top_k: int = 0
    n: int = 1
    stream: bool = False
    priority: int = 0
    tier: str = "online"    # hybrid serving (docs/hybrid.md)
    tenant: str = "anonymous"
    echo_prompt: bool = False

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0

    def sampling_params(self) -> SamplingParams:
        return SamplingParams(
            temperature=self.temperature if not self.greedy else 1.0,
            top_p=self.top_p, top_k=self.top_k, greedy=self.greedy,
            max_new_tokens=self.max_tokens, n=self.n,
            priority=self.priority, tier=self.tier)


def parse_completion_request(body: Dict[str, Any], vocab_size: int, *,
                             tenant: Optional[str] = None,
                             max_tokens_cap: int = 0) -> CompletionRequest:
    """Validate a decoded JSON body into a :class:`CompletionRequest`.

    ``tenant`` is the transport-layer key (``X-API-Key`` header /
    ``Authorization: Bearer`` token); it wins over the body's OpenAI
    ``user`` field.  ``max_tokens_cap`` > 0 clamps the per-request
    output budget (the server passes the engine's room)."""
    if not isinstance(body, dict):
        raise ProtocolError("request body must be a JSON object")

    def field(name, typ, default):
        v = body.get(name, default)
        if typ is float and isinstance(v, int) and not isinstance(v, bool):
            v = float(v)
        # JSON true/false must not pass int/float checks (bool subclasses int)
        if not isinstance(v, typ) or (typ is not bool
                                      and isinstance(v, bool)):
            raise ProtocolError(f"{name!r} must be {typ.__name__}, "
                                f"got {type(v).__name__}")
        return v

    if "prompt" not in body:
        raise ProtocolError("missing required field 'prompt'")
    prompt_ids = encode_prompt(body["prompt"], vocab_size)
    max_tokens = field("max_tokens", int, 16)
    if max_tokens < 1:
        raise ProtocolError(f"max_tokens must be >= 1, got {max_tokens}")
    if max_tokens_cap:
        max_tokens = min(max_tokens, max_tokens_cap)
    n = field("n", int, 1)
    if not 1 <= n <= 8:
        raise ProtocolError(f"n must be in [1, 8], got {n}")
    temperature = field("temperature", float, 1.0)
    if temperature < 0.0:
        raise ProtocolError("temperature must be >= 0")
    top_p = field("top_p", float, 1.0)
    if not 0.0 < top_p <= 1.0:
        raise ProtocolError("top_p must be in (0, 1]")
    tier = field("tier", str, "online")
    if tier not in ("online", "offline"):
        raise ProtocolError(
            f"tier must be 'online' or 'offline', got {tier!r}")
    return CompletionRequest(
        prompt_ids=prompt_ids,
        model=field("model", str, "repro"),
        max_tokens=max_tokens,
        temperature=temperature,
        top_p=top_p,
        top_k=field("top_k", int, 0),
        n=n,
        stream=field("stream", bool, False),
        priority=field("priority", int, 0),
        tier=tier,
        tenant=tenant or field("user", str, "anonymous"),
    )


# ---------------------------------------------------------------------------
# SSE framing + completion JSON
# ---------------------------------------------------------------------------

def sse_event(payload: Dict[str, Any]) -> bytes:
    """One Server-Sent-Events frame: ``data: <json>\\n\\n`` (compact
    separators, sorted keys — byte-stable for the golden tests)."""
    return b"data: " + json.dumps(
        payload, separators=(",", ":"), sort_keys=True).encode() + b"\n\n"


def _choice(index: int, token_ids: List[int],
            finish_reason: Optional[str]) -> Dict[str, Any]:
    return {
        "index": index,
        "text": decode_text(token_ids),
        "token_ids": [int(t) for t in token_ids],
        "finish_reason": finish_reason,
        "logprobs": None,
    }


def completion_chunk(request_id: int, created: int, model: str, index: int,
                     new_token_ids: List[int],
                     finish_reason: Optional[str] = None) -> Dict[str, Any]:
    """One streaming increment for one choice (SSE ``data:`` payload).
    ``text``/``token_ids`` carry only the DELTA since the previous chunk
    of this choice; the terminal chunk repeats an empty delta with the
    ``finish_reason`` set when the final tokens already streamed."""
    return {
        "id": f"cmpl-{request_id}",
        "object": "text_completion.chunk",
        "created": created,
        "model": model,
        "choices": [_choice(index, new_token_ids, finish_reason)],
    }


def completion_response(request_id: int, created: int, model: str,
                        choices: List[Dict[str, Any]], prompt_tokens: int,
                        ) -> Dict[str, Any]:
    """The non-streaming (``stream=false``) aggregate response.

    ``choices`` entries are ``{"token_ids": [...], "finish_reason": ...}``
    in completion-index order; usage counts come straight from the
    request's token lists (RequestOutput accounting)."""
    completion_tokens = sum(len(c["token_ids"]) for c in choices)
    return {
        "id": f"cmpl-{request_id}",
        "object": "text_completion",
        "created": created,
        "model": model,
        "choices": [_choice(i, c["token_ids"], c["finish_reason"])
                    for i, c in enumerate(choices)],
        "usage": {
            "prompt_tokens": prompt_tokens,
            "completion_tokens": completion_tokens,
            "total_tokens": prompt_tokens + completion_tokens,
        },
    }


# ---------------------------------------------------------------------------
# Prometheus text exposition
# ---------------------------------------------------------------------------

def _metric_name(key: str) -> str:
    return "repro_" + "".join(c if c.isalnum() or c == "_" else "_"
                              for c in key)


def render_prometheus(per_replica: Dict[str, Dict[str, Any]],
                      extra: Optional[Dict[str, Any]] = None) -> str:
    """Prometheus text format over ``engine.metrics()`` snapshots.

    Scalar numeric fields become ``repro_<key>{replica="<name>"}`` gauge
    lines; nested structures (per-request records, stage lists) are
    skipped — they are debugging payload, not time series.  ``extra``
    adds unlabeled server-level series (admission counters)."""
    lines: List[str] = []
    for name, metrics in sorted(per_replica.items()):
        for key in sorted(metrics):
            val = metrics[key]
            if isinstance(val, bool) or not isinstance(val, (int, float)):
                continue
            lines.append(
                f'{_metric_name(key)}{{replica="{name}"}} {float(val):g}')
    for key in sorted(extra or {}):
        val = (extra or {})[key]
        if isinstance(val, bool) or not isinstance(val, (int, float)):
            continue
        lines.append(f"{_metric_name(key)} {float(val):g}")
    return "\n".join(lines) + "\n"
